"""Logical-axis sharding rules + HLO analysis (subprocess for multi-device)."""

import json
import os
import subprocess
import sys
import textwrap
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as sh
from repro.models.module import ParamSpec


class TestRules:
    def setup_method(self):
        self.mesh = jax.make_mesh((1,), ("model",))

    def test_divisibility_drops_axis(self):
        rules = sh.make_rules(mlp="model")
        mesh = jax.make_mesh((1,), ("model",))
        spec = sh.logical_to_spec(("embed", "mlp"), (64, 64), mesh, rules)
        assert isinstance(spec, P)

    def test_no_mesh_is_noop(self):
        x = jnp.ones((4, 4))
        assert sh.shard_activation(x, ("batch", None)) is x

    def test_axis_used_once(self):
        # experts and mlp both want "model": only the first gets it
        mesh = jax.make_mesh((1,), ("model",))
        spec = sh.logical_to_spec(("experts", "embed", "mlp"), (4, 8, 16),
                                  mesh, sh.DEFAULT_RULES)
        flat = [s for s in spec if s is not None]
        names = []
        for s in flat:
            names.extend(s if isinstance(s, tuple) else (s,))
        assert len(names) == len(set(names))

    def test_params_shardings_tree(self):
        mesh = jax.make_mesh((1,), ("model",))
        specs = {"w": ParamSpec((8, 16), ("embed", "mlp"))}
        shards = sh.params_shardings(specs, mesh)
        assert shards["w"] is not None


# logical axis names that DEFAULT_RULES maps to mesh axes, plus unmapped
# names and bare None dims -- the property sweep draws tuples of these
_AXIS_NAMES = st.sampled_from(
    ["embed", "mlp", "heads", "kv", "vocab", "experts", "batch",
     "act_mlp", "act_heads", "layers", "kv_seq", "not_a_rule", None])


class TestRuleProperties:
    """The two GSPMD invariants of ``logical_to_spec``, swept over random
    (axes, shape, mesh-size) combinations.  ``logical_to_spec`` only
    reads ``mesh.shape``, so a stub namespace stands in for a real Mesh
    -- multi-axis meshes get property-tested on a 1-device runtime."""

    @given(axes=st.lists(_AXIS_NAMES, min_size=1, max_size=5),
           dims=st.lists(st.integers(1, 8), min_size=5, max_size=5),
           data=st.integers(1, 4), model=st.sampled_from([1, 2, 4, 8]))
    @settings(max_examples=200, deadline=None)
    def test_mesh_axis_consumed_at_most_once(self, axes, dims, data,
                                             model):
        mesh = types.SimpleNamespace(shape={"data": data, "model": model})
        shape = tuple(d * 4 for d in dims[:len(axes)])
        spec = sh.logical_to_spec(tuple(axes), shape, mesh)
        names = []
        for entry in spec:
            if entry is None:
                continue
            names.extend(entry if isinstance(entry, tuple) else (entry,))
        assert len(names) == len(set(names)), \
            f"mesh axis consumed twice: {spec} for axes={axes}"
        assert all(n in mesh.shape for n in names)

    @given(axes=st.lists(_AXIS_NAMES, min_size=1, max_size=5),
           dims=st.lists(st.integers(1, 33), min_size=5, max_size=5),
           model=st.sampled_from([2, 4, 8]))
    @settings(max_examples=200, deadline=None)
    def test_non_divisible_dims_replicate(self, axes, dims, model):
        mesh = types.SimpleNamespace(shape={"model": model})
        shape = tuple(dims[:len(axes)])
        spec = sh.logical_to_spec(tuple(axes), shape, mesh)
        for name, dim, entry in zip(axes, shape, spec):
            if entry is None:
                continue
            picked = entry if isinstance(entry, tuple) else (entry,)
            span = 1
            for ax in picked:
                span *= mesh.shape[ax]
            assert dim % span == 0, \
                f"dim {dim} sharded {span}-way: {spec} for axes={axes}"

    def test_spec_matches_manual_resolution(self):
        # pinned example: first logical axis wins the contested axis,
        # the non-divisible dim replicates
        mesh = types.SimpleNamespace(shape={"data": 2, "model": 4})
        spec = sh.logical_to_spec(("mlp", "heads", "batch"), (8, 6, 4),
                                  mesh)
        assert tuple(spec) == ("model", None, "data")

    def test_no_mesh_noop_is_exact(self):
        # `is`-identity, not just equality: the single-device serving
        # path must never pay a copy or a trace-level constraint
        for shape in ((1,), (2, 3), (2, 3, 4)):
            x = jnp.ones(shape)
            assert sh.shard_activation(x, ("batch",) + (None,) *
                                       (len(shape) - 1)) is x


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis.hlo import analyze_hlo, collective_stats
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((2, 4), ("data", "model"))

    def f(ws, x):
        def step(x, w):
            return x @ w, None
        y, _ = jax.lax.scan(step, x, ws)
        return y.sum()

    ws = jax.ShapeDtypeStruct((5, 256, 256), jnp.float32,
        sharding=NamedSharding(mesh, P(None, None, "model")))
    xs = jax.ShapeDtypeStruct((64, 256), jnp.float32,
        sharding=NamedSharding(mesh, P("data", None)))
    with mesh:
        comp = jax.jit(f).lower(ws, xs).compile()
    costs = analyze_hlo(comp.as_text())
    print(json.dumps({{
        "dot_flops": costs.dot_flops,
        "ag_bytes": costs.collectives.bytes_by_op["all-gather"],
        "unknown_trips": costs.collectives.unknown_trip_counts,
    }}))
""")


class TestHloAnalysis:
    def test_loop_aware_accounting(self, tmp_path):
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        script = MULTIDEV_SCRIPT.format(src=os.path.abspath(src))
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        res = json.loads(out.stdout.strip().splitlines()[-1])
        # scan body executes 5x: per-device dot flops = 5 * 2*32*256*64
        assert res["dot_flops"] == pytest.approx(5 * 2 * 32 * 256 * 64)
        # all-gather of the x shard inside the loop: 32*256*4 bytes x 5
        assert res["ag_bytes"] == pytest.approx(32 * 256 * 4 * 5)
        assert res["unknown_trips"] == 0

    def test_shape_bytes_parser(self):
        from repro.analysis.hlo import _shape_bytes
        assert _shape_bytes("bf16[4,8]{1,0}") == 64
        assert _shape_bytes("(f32[2,2], s32[3])") == 28
        assert _shape_bytes("pred[7]") == 7
        assert _shape_bytes("token[]") == 0

    def test_collective_stats_simple_text(self):
        from repro.analysis.hlo import collective_stats
        hlo = textwrap.dedent("""\
            HloModule m

            ENTRY %main (a: f32[16]) -> f32[16] {
              %a = f32[16]{0} parameter(0)
              ROOT %ar = f32[16]{0} all-reduce(%a), channel_id=1
            }
            """)
        st = collective_stats(hlo)
        assert st.bytes_by_op["all-reduce"] == 64.0


class TestMeshBuilders:
    def test_elastic_mesh_single_device(self):
        from repro.launch.mesh import make_elastic_mesh
        mesh = make_elastic_mesh(1, model_parallel=16)
        assert int(np.prod(list(mesh.shape.values()))) == 1

    def test_elastic_mesh_zero_devices_raises(self):
        # regression: used to silently build a (1, 0) mesh after total
        # host loss instead of telling the caller to re-enumerate
        from repro.launch.mesh import make_elastic_mesh
        with pytest.raises(ValueError, match="at least one device"):
            make_elastic_mesh(0)
        with pytest.raises(ValueError, match="at least one device"):
            make_elastic_mesh(-2, model_parallel=4)

    def test_elastic_mesh_bad_model_parallel_raises(self):
        from repro.launch.mesh import make_elastic_mesh
        with pytest.raises(ValueError, match="model_parallel must be"):
            make_elastic_mesh(8, model_parallel=0)
        with pytest.raises(ValueError, match="model_parallel must be"):
            make_elastic_mesh(8, model_parallel=-1)

    def test_elastic_mesh_nonviable_divisor_raises(self):
        # regression: mp=3 with 8 devices used to silently fall back to
        # a (1, 8) pure-TP mesh, ignoring the requested TP degree
        from repro.launch.mesh import make_elastic_mesh
        with pytest.raises(ValueError, match="cannot tile"):
            make_elastic_mesh(8, model_parallel=3)

    def test_elastic_mesh_tiny_fallback_still_works(self):
        # fewer devices than model_parallel is the test regime, not an
        # error: fall back to a (1, avail) mesh
        from repro.launch.mesh import make_elastic_mesh
        mesh = make_elastic_mesh(1, model_parallel=4)
        assert dict(mesh.shape) == {"data": 1, "model": 1}

    def test_production_mesh_shapes_via_subprocess(self):
        src = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        script = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
            import sys; sys.path.insert(0, {src!r})
            from repro.launch.mesh import make_production_mesh
            m1 = make_production_mesh()
            m2 = make_production_mesh(multi_pod=True)
            assert dict(m1.shape) == {{"data": 16, "model": 16}}, m1.shape
            assert dict(m2.shape) == {{"pod": 2, "data": 16, "model": 16}}
            print("OK")
        """)
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout
