"""Self-speculative decoding invariants (serving/batch.spec_chunk).

The speculative path's contract is EXACT-MATCH acceptance: emitted
tokens are always the verifier's own choices sampled with the same
per-slot PRNG subkeys the sequential path would use, so the output
stream is token-identical to the non-speculative engine -- greedy or
sampled -- and the draft only moves tokens-per-tick.  This file pins
that contract down:

  - acceptance-run parity: greedy spec == contiguous oracle across the
    four arch families x contiguous/paged/shared modes (families with
    ring/recurrent cache state must gate speculation INERT, same
    predicate as share_prefix, and still match);
  - temperature parity (the PRNG-chain bookkeeping, not just argmax);
  - full-acceptance runs via a self-draft (draft == verifier): every
    tick commits k+1 tokens, EOS inside an accepted run truncates it
    on device;
  - k=0 degenerates bit-identically to the existing decode path;
  - draft-cache rollback leaves bystander slots untouched (staggered
    mixed-length requests each match their solo oracle);
  - the one-host-sync-per-tick contract: a counting numpy proxy sees
    exactly 2 device->host transfers per pure-decode tick (toks,
    emitted), speculative or not;
  - core/deploy.truncate_params structure (period slicing, leftover
    remainder blocks, validation);
  - the scheduler's over-emission guard (variable tokens-per-tick must
    never exceed a slot's remaining budget).

Run via ``make test-spec`` or the serving CI tier.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.core import deploy
from repro.models import module as M
from repro.models import transformer as T
from repro.serving import engine as engine_mod
from repro.serving.engine import Engine, SamplerConfig
from repro.serving.scheduler import Scheduler

ARCHS = ["granite-8b",          # linear KV: speculation LIVE
         "gemma2-2b",           # ring local KV mix: gated inert
         "falcon-mamba-7b",     # SSM state: gated inert
         "recurrentgemma-2b"]   # RG-LRU + ring: gated inert

PAGE, MAX_SEQ, CAP = 8, 32, 2
ENGINE_KW = dict(prefill_bucket=4, prefill_chunk_width=8, capacity=CAP,
                 max_seq=MAX_SEQ, chunk=3)
MODE_KW = [dict(),
           dict(paged=True, page_size=PAGE),
           dict(paged=True, page_size=PAGE, share_prefix=True)]


def small_model(arch="granite-8b", seed=0, **over):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              dtype=jnp.float32, **over)
    params = M.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


def spec_executor(eng):
    return eng._executors[next(iter(eng._executors))]


@pytest.fixture(scope="module")
def granite():
    return small_model()


class TestParity:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_greedy_parity_all_modes(self, arch):
        """Greedy spec output == contiguous oracle, across contiguous /
        paged / paged+share.  Only the pure-attention family may engage
        speculation; the others must gate it inert and still match."""
        cfg, params = small_model(arch)
        rng = np.random.default_rng(17)
        prompts = {"tokens": rng.integers(
            0, cfg.vocab, (CAP, 6)).astype(np.int32)}
        ref = Engine(params, cfg, **ENGINE_KW).generate(
            prompts, max_new=10, mode="continuous")
        for kw in MODE_KW:
            eng = Engine(params, cfg, speculative=True, k=3, **kw,
                         **ENGINE_KW)
            out = eng.generate(prompts, max_new=10, mode="continuous")
            np.testing.assert_array_equal(
                out, ref, err_msg=f"{arch} {kw}: spec diverged")
            ex = spec_executor(eng)
            if arch == "granite-8b":
                assert ex.spec and ex.spec_tokens >= ex.spec_slots > 0
            else:
                assert not ex.spec       # inert, engine still correct

    def test_temperature_parity(self, granite):
        """Sampled (temperature > 0) spec output is token-identical too:
        every emitted token uses the slot's sequential PRNG subkey, and
        the slot key advances by exactly the committed count."""
        cfg, params = granite
        rng = np.random.default_rng(23)
        prompts = {"tokens": rng.integers(
            0, cfg.vocab, (CAP, 5)).astype(np.int32)}
        sampler = SamplerConfig(temperature=0.8, seed=11)
        ref = Engine(params, cfg, sampler, **ENGINE_KW).generate(
            prompts, max_new=12, mode="continuous")
        for kw in MODE_KW:
            eng = Engine(params, cfg, sampler, speculative=True, k=3,
                         **kw, **ENGINE_KW)
            out = eng.generate(prompts, max_new=12, mode="continuous")
            np.testing.assert_array_equal(
                out, ref, err_msg=f"temp parity diverged under {kw}")

    def test_bystander_rollback_isolation(self, granite):
        """Draft-cache rollback is per-slot length accounting: one slot
        rejecting (and rolling back) every tick must not perturb its
        neighbor.  Staggered mixed-length requests through a capacity-2
        spec engine each match their SOLO contiguous oracle run."""
        cfg, params = granite
        rng = np.random.default_rng(31)
        reqs = [(rng.integers(0, cfg.vocab, (7,)).astype(np.int32), 12),
                (rng.integers(0, cfg.vocab, (3,)).astype(np.int32), 4),
                (rng.integers(0, cfg.vocab, (12,)).astype(np.int32), 6)]
        eng = Engine(params, cfg, speculative=True, k=3, **ENGINE_KW)
        rids = [eng.submit({"tokens": p[None]}, max_new=mn,
                           arrival=float(i))
                for i, (p, mn) in enumerate(reqs)]
        res = eng.drain()
        for rid, (p, mn) in zip(rids, reqs):
            solo = Engine(params, cfg, **{**ENGINE_KW, "capacity": 1})
            srid = solo.submit({"tokens": p[None]}, max_new=mn)
            np.testing.assert_array_equal(
                res[rid], solo.drain()[srid],
                err_msg=f"rid {rid} perturbed by its neighbors")


class TestAcceptedRuns:
    def test_self_draft_multi_token_commits(self, granite):
        """A self-draft (draft == verifier weights) keeps acceptance
        high enough that multi-token commit paths -- not just the
        reject-all fallback -- actually execute, while the output stays
        the oracle's.  (Acceptance is not 100%: the draft conditions on
        decode-written cache entries, the verifier on window-written
        ones, and random-weight near-ties flip under that ulp noise.)"""
        cfg, params = granite
        rng = np.random.default_rng(41)
        prompts = {"tokens": rng.integers(
            0, cfg.vocab, (1, 4)).astype(np.int32)}
        ref = Engine(params, cfg, **ENGINE_KW).generate(
            prompts, max_new=13, mode="continuous")
        eng = Engine(params, cfg, speculative=True, k=3,
                     draft=(params, cfg), **ENGINE_KW)
        out = eng.generate(prompts, max_new=13, mode="continuous")
        np.testing.assert_array_equal(out, ref)
        ex = spec_executor(eng)
        assert ex.spec_tokens == 12          # tok0 came from prefill
        assert ex.spec_ticks < 12, \
            "every tick committed a single token -- the accepted-run " \
            "path (tokens-per-tick > 1) never executed"

    def test_eos_mid_accepted_run_truncates(self, granite):
        """EOS strictly inside an accepted run truncates it ON DEVICE.

        Driven at the spec_chunk level so the mid-run placement is
        forced, not hoped for: tick the device state forward until a
        tick commits m >= 3 tokens (slot states are immutable pytrees,
        so that tick's INPUT state is still at hand), then replay the
        same tick with eos_id = its second token.  The replay must emit
        exactly tokens v[0], v[1] and roll both length accountings back
        to +2."""
        cfg, params = granite
        rng = np.random.default_rng(41)
        eng = Engine(params, cfg, speculative=True, k=3,
                     draft=(params, cfg), **{**ENGINE_KW, "capacity": 1})
        rid = eng.submit({"tokens": rng.integers(
            0, cfg.vocab, (1, 4)).astype(np.int32)}, max_new=26)
        eng.step()                           # prefill -> RUNNING
        assert eng._sched.requests[rid].status == "running"
        ex = eng._sched.ex
        active = jnp.asarray(np.array([True]))
        no_eos = jnp.asarray(np.array([-1], np.int32))
        state, dstate, remaining, found = ex.state, ex.draft_state, 25, None
        for _ in range(8):
            st, dst, toks, emitted = ex._spec_chunk(
                ex.params, ex.draft_params, state, dstate, active,
                jnp.asarray(np.array([remaining], np.int32)), no_eos,
                None)
            m = int(np.asarray(emitted).sum())
            if m >= 3:
                found = (state, dstate, remaining, np.asarray(toks))
                break
            state, dstate, remaining = st, dst, remaining - m
        assert found is not None, \
            "no tick committed a 3+-token run in 8 tries (self-draft " \
            "acceptance collapsed?)"
        state, dstate, remaining, toks0 = found
        eos = int(toks0[1, 0])               # the run's SECOND token
        st, dst, toks1, emitted1 = ex._spec_chunk(
            ex.params, ex.draft_params, state, dstate, active,
            jnp.asarray(np.array([remaining], np.int32)),
            jnp.asarray(np.array([eos], np.int32)), None)
        emitted1 = np.asarray(emitted1)
        assert emitted1.sum() == 2, \
            f"EOS at run position 1 should truncate to 2 tokens, " \
            f"got {emitted1.sum()}"
        np.testing.assert_array_equal(np.asarray(toks1)[:2, 0],
                                      toks0[:2, 0])
        base_len = int(np.asarray(state.lengths)[0])
        assert int(np.asarray(st.lengths)[0]) == base_len + 2
        assert int(np.asarray(dst.lengths)[0]) == base_len + 2

    def test_eos_parity_engine_level(self, granite):
        """Engine-level EOS: the speculative stream stops exactly where
        the contiguous oracle's does, wherever the EOS happens to land
        relative to run boundaries."""
        cfg, params = granite
        rng = np.random.default_rng(43)
        p = rng.integers(0, cfg.vocab, (1, 4)).astype(np.int32)
        oracle = Engine(params, cfg, **{**ENGINE_KW, "capacity": 1})
        orid = oracle.submit({"tokens": p}, max_new=12)
        full = oracle.drain()[orid]
        eos = int(full[5])
        want = full[:int(np.nonzero(full == eos)[0][0]) + 1]
        eng = Engine(params, cfg, speculative=True, k=3,
                     draft=(params, cfg), **{**ENGINE_KW, "capacity": 1})
        rid = eng.submit({"tokens": p}, max_new=12, eos_id=eos)
        got = eng.drain()[rid]
        np.testing.assert_array_equal(got, want)
        assert got.shape[0] < 12, "EOS never truncated the stream"

    def test_k0_bit_identical_bypass(self, granite):
        """speculative=True with k=0 must be the EXISTING decode path,
        bit-identically: the executor never builds draft state and runs
        the plain chunk scan."""
        cfg, params = granite
        rng = np.random.default_rng(47)
        prompts = {"tokens": rng.integers(
            0, cfg.vocab, (CAP, 6)).astype(np.int32)}
        base = Engine(params, cfg, **ENGINE_KW)
        eng = Engine(params, cfg, speculative=True, k=0, **ENGINE_KW)
        np.testing.assert_array_equal(
            eng.generate(prompts, max_new=9, mode="continuous"),
            base.generate(prompts, max_new=9, mode="continuous"))
        ex = spec_executor(eng)
        assert not ex.spec and not hasattr(ex, "draft_state")


class TestHostSyncContract:
    def test_one_host_sync_per_decode_tick(self, granite):
        """The draft->verify round-trip must not bounce through the
        host: a pure-decode tick performs exactly the 2 device->host
        transfers the plain path does (toks + emitted of the one fused
        call), speculative or not."""
        cfg, params = granite

        class CountingNp:
            """numpy proxy counting asarray calls on DEVICE arrays."""

            def __init__(self):
                self.count = 0

            def __getattr__(self, name):
                return getattr(np, name)

            def asarray(self, x, *a, **kw):
                if isinstance(x, jax.Array):
                    self.count += 1
                return np.asarray(x, *a, **kw)

        for spec_kw in (dict(), dict(speculative=True, k=3),
                        dict(speculative=True, k=3,
                             draft=(params, cfg))):
            eng = Engine(params, cfg, **spec_kw, **ENGINE_KW)
            rid = eng.submit({"tokens": np.arange(4, dtype=np.int32)[None]
                              % cfg.vocab}, max_new=20)
            eng.step()                       # admission tick (prefill)
            assert eng._sched.requests[rid].status == "running"
            proxy = CountingNp()
            real = engine_mod.np
            engine_mod.np = proxy
            try:
                eng.step()                   # pure decode tick
            finally:
                engine_mod.np = real
            assert proxy.count == 2, \
                f"{spec_kw}: decode tick made {proxy.count} " \
                f"device->host transfers, contract is 2 (toks, emitted)"


class TestTruncateParams:
    def test_period_and_leftover_slicing(self):
        """8 layers of pattern (rec, rec, attn_local) truncated to 4:
        one full period stays stacked, the leftover block becomes an
        unstacked remainder sliced from period index 1."""
        cfg, params = small_model("recurrentgemma-2b")
        assert cfg.n_layers == 8 and len(cfg.block_pattern) == 3
        draft, dcfg = deploy.truncate_params(params, cfg, 4)
        assert dcfg.n_layers == 4 and dcfg.n_periods == 1
        assert dcfg.remainder_pattern == cfg.block_pattern[:1]
        for full, cut in zip(params["period"], draft["period"]):
            fl, cl = jax.tree.leaves(full), jax.tree.leaves(cut)
            for f, c in zip(fl, cl):
                assert c.shape == (1,) + f.shape[1:]
                np.testing.assert_array_equal(np.asarray(c),
                                              np.asarray(f[:1]))
        assert len(draft["remainder"]) == 1
        want = jax.tree.leaves(jax.tree.map(lambda x: x[1],
                                            params["period"][0]))
        got = jax.tree.leaves(draft["remainder"][0])
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        # non-period leaves pass through shared
        assert draft["final_norm"] is params["final_norm"]

    def test_draft_is_a_runnable_model(self, granite):
        """The sliced tree runs through prefill with the sliced config
        and equals the full model's value after its first n_layers
        blocks -- checked indirectly: a 1-layer draft of a 2-layer model
        differs from the full model (the slice is real, not aliased)."""
        cfg, params = granite
        draft, dcfg = deploy.truncate_params(params, cfg, 1)
        toks = jnp.arange(6, dtype=jnp.int32)[None] % cfg.vocab
        batch = {"tokens": toks, "prompt_lengths": jnp.asarray([6])}
        lg_d, _, _ = T.prefill(draft, dcfg, batch, 16)
        lg_f, _, _ = T.prefill(params, cfg, batch, 16)
        assert lg_d.shape == lg_f.shape
        assert not np.allclose(np.asarray(lg_d), np.asarray(lg_f))

    def test_validation(self, granite):
        cfg, params = granite
        for bad in (0, cfg.n_layers, cfg.n_layers + 1):
            with pytest.raises(ValueError, match="draft n_layers"):
                deploy.truncate_params(params, cfg, bad)
        with pytest.raises(ValueError, match="draft_layers"):
            Engine(params, cfg, speculative=True,
                   draft_layers=cfg.n_layers, **ENGINE_KW)
        with pytest.raises(ValueError, match="not both"):
            Engine(params, cfg, speculative=True, draft=(params, cfg),
                   draft_layers=1, **ENGINE_KW)
        with pytest.raises(ValueError, match="k must be"):
            Engine(params, cfg, speculative=True, k=-1, **ENGINE_KW)


class TestOverEmissionGuard:
    def test_scheduler_rejects_over_budget_emission(self, granite):
        """Variable tokens-per-tick hardening: an executor emitting more
        tokens than a slot's remaining budget is a bug the scheduler
        must fail loudly on (a page reservation never covered them)."""
        cfg, params = granite
        eng = Engine(params, cfg, speculative=True, k=3,
                     **{**ENGINE_KW, "capacity": 1})
        rid = eng.submit({"tokens": np.arange(4, dtype=np.int32)[None]
                          % cfg.vocab}, max_new=3)
        eng.step()                           # prefill; 1 of 3 emitted
        assert eng._sched.requests[rid].status == "running"
        ex = eng._sched.ex
        orig = ex.run_chunk

        def over_emitting(active, remaining, eos_ids):
            toks, emitted = orig(active, remaining, eos_ids)
            return toks, np.ones_like(emitted)      # claims k+1 = 4 > 2
        ex.run_chunk = over_emitting
        with pytest.raises(RuntimeError, match="remaining"):
            eng.step()
